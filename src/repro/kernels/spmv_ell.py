"""Pallas TPU kernel: ELL-format SpMV — the VPU/sparse-path hot spot.

The sparse path stores light blocks as padded neighbor lists (ELLPACK:
``idx`` (R, K) column indices + validity mask).  y[r] = Σ_k x[idx[r,k]]
for valid k — a gather + row reduction, the shape of PageRank/BFS work
on blocks too sparse for the bitmap/MXU path.

Tiling: grid (R/br,); each step holds a (br, K) index/mask panel and the
full x vector in VMEM (the block-list bound: the engine only hands this
kernel blocks whose source range fits one tile, so x here is a stripe
slice, not the whole graph — the same VMEM bounding the paper uses
device memory for).  Gathers lower to VPU dynamic loads on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(idx_ref, val_ref, x_ref, y_ref):
    idx = idx_ref[0]                         # (br, K) int32
    msk = val_ref[0]                         # (br, K) float (0/1)
    x = x_ref[0]                             # (N,)
    gathered = x[idx]                        # (br, K) VPU gather
    y_ref[0, :] = jnp.sum(gathered * msk, axis=1)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def spmv_ell(idx, valid, x, *, block_r: int = 128, interpret: bool = True):
    """(B,R,K) idx + (B,R,K) mask + (B,N) x → (B,R) row sums of x[idx]."""
    b, r, k = idx.shape
    n = x.shape[1]
    br = min(block_r, r)
    assert r % br == 0
    return pl.pallas_call(
        _kernel,
        grid=(b, r // br),
        in_specs=[
            pl.BlockSpec((1, br, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, br, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, br), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, r), x.dtype),
        interpret=interpret,
    )(idx, valid.astype(x.dtype), x)
