"""Pallas TPU kernel: bottom-up BFS frontier probe (BFS's K_D hot spot).

For each row u of a packed bitmap tile, find the smallest local column c
such that (u, c) is an edge AND c is in the frontier — the GPU bottom-up
step of the paper's Listing 3 ("if one of its neighbors appears in the
frontier, insert and stop") as a masked VPU row-reduction.  The "stop at
the first neighbor" early exit becomes a min-reduction, which is the
deterministic TPU equivalent.

Grid (nd, T/bt): each step loads a (bt, T) row panel and the (T,)
frontier mask; working set bt·T + T floats (≤0.6 MiB at T=1024).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INT_MAX = np.int32(2**31 - 1)  # numpy scalar: not a captured jax constant


def _kernel(a_ref, f_ref, out_ref):
    a = a_ref[0]                             # (bt, T) tile row panel
    f = f_ref[0]                             # (T,) frontier mask (float/int)
    bt, t = a.shape
    colid = jax.lax.broadcasted_iota(jnp.int32, (bt, t), 1)
    hit = (a > 0) & (f[None, :] > 0)
    out_ref[0, :] = jnp.where(hit, colid, _INT_MAX).min(axis=1)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def frontier_tiles(tiles, fcols, *, block_t: int = 128, interpret: bool = True):
    """(nd,T,T) tiles × (nd,T) frontier → (nd,T) i32 min frontier column."""
    nb, t, _ = tiles.shape
    if block_t <= 0:
        raise ValueError(f"block_t must be a positive int; got {block_t!r}")
    # the row-panel height must divide T exactly or the BlockSpec grid
    # misses rows; shrink to the largest divisor of T ≤ block_t so
    # non-power-of-two tile dims (192, 96, ...) run correctly instead
    # of tripping a bare assert (which vanishes under ``python -O``)
    bt = max(min(block_t, t), 1)
    while t % bt:
        bt -= 1
    return pl.pallas_call(
        _kernel,
        grid=(nb, t // bt),
        in_specs=[
            pl.BlockSpec((1, bt, t), lambda b, r: (b, r, 0)),
            pl.BlockSpec((1, t), lambda b, r: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt), lambda b, r: (b, r)),
        out_shape=jax.ShapeDtypeStruct((nb, t), jnp.int32),
        interpret=interpret,
    )(tiles, fcols.astype(tiles.dtype))
