"""Kernel-backend registry: named kernels × {reference, xla, pallas}.

Replaces the boolean ``use_pallas`` flag.  Each hot-spot kernel is
registered once per backend it supports; dispatch happens at trace time
(the backend name is static aux data on the :class:`~repro.core.context.Context`),
so the jitted step bakes in exactly one implementation.

Backends
--------
``reference``
    The pure-jnp oracle from :mod:`repro.kernels.ref` — the mathematical
    definition, used by tests and as the last-resort fallback.
``xla``
    The vectorized einsum/gather formulation that XLA fuses well — the
    default on any backend.
``pallas``
    The hand-tiled Pallas kernels (native on TPU, ``interpret=True``
    elsewhere).

Resolution falls back down the chain ``pallas → xla → reference`` when
a backend is unavailable or a kernel has no registration for it, so
``backend="pallas"`` degrades cleanly instead of erroring on hosts
without a working Pallas lowering.  Dense and sparse paths dispatch
independently — registration is per kernel name, not global.
"""
from __future__ import annotations

from typing import Callable

__all__ = [
    "BACKENDS", "register_kernel", "get_kernel", "resolve_backend",
    "pallas_available", "registered", "register_workspace", "workspace_bytes",
    "max_workspace_bytes", "registered_workspaces",
    "register_host_executable", "host_executable",
    "registered_host_executable",
]

BACKENDS = ("reference", "xla", "pallas")
_FALLBACK = {"pallas": "xla", "xla": "reference"}

_REGISTRY: dict[tuple[str, str], Callable] = {}

# Test hook: force the availability probe (None = auto-detect).
_FORCE_PALLAS_AVAILABLE: bool | None = None


def pallas_available() -> bool:
    """Whether a Pallas lowering path exists in this runtime."""
    if _FORCE_PALLAS_AVAILABLE is not None:
        return _FORCE_PALLAS_AVAILABLE
    try:
        import jax.experimental.pallas  # noqa: F401

        from . import ops  # noqa: F401
    except Exception:  # pragma: no cover — container without pallas
        return False
    return True


def resolve_backend(backend: str) -> str:
    """Validate ``backend`` and apply availability fallback.

    ``pallas`` silently degrades to ``xla`` when no Pallas runtime is
    importable; unknown names raise.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {BACKENDS}"
        )
    if backend == "pallas" and not pallas_available():
        return "xla"
    return backend


def register_kernel(name: str, backend: str) -> Callable[[Callable], Callable]:
    """Decorator: register ``fn`` as the ``backend`` implementation of ``name``."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")

    def deco(fn: Callable) -> Callable:
        _REGISTRY[(name, backend)] = fn
        return fn

    return deco


def get_kernel(name: str, backend: str) -> Callable:
    """Resolve ``name`` for ``backend``, walking the fallback chain."""
    b = resolve_backend(backend)
    while True:
        fn = _REGISTRY.get((name, b))
        if fn is not None:
            return fn
        if b not in _FALLBACK:
            raise KeyError(
                f"kernel {name!r} has no registration reachable from "
                f"backend {backend!r}"
            )
        b = _FALLBACK[b]


def registered(name: str) -> dict[str, Callable]:
    """All registered implementations of ``name``, keyed by backend."""
    return {b: fn for (n, b), fn in _REGISTRY.items() if n == name}


# ----------------------------------------------------------------------
# Host-executable capability: kernel names certified safe to run
# eagerly on the host CPU (pure jnp reference path, no Pallas/XLA
# custom calls, bit-identical int/bool results).  The heterogeneous
# streaming executor consults this before peeling an algorithm's tasks
# to the host lane — an algorithm that names an uncertified kernel in
# metadata["host_kernels"] stays device-only.
_HOST_OK: set[str] = set()


def register_host_executable(name: str) -> None:
    """Certify kernel ``name`` as host-executable (see module docs)."""
    _HOST_OK.add(str(name))


def host_executable(name: str) -> bool:
    """Whether ``name`` is certified to run on the host CPU lane."""
    return str(name) in _HOST_OK


def registered_host_executable() -> tuple[str, ...]:
    """Sorted names currently certified host-executable."""
    return tuple(sorted(_HOST_OK))


# ----------------------------------------------------------------------
# Per-kernel workspace estimators: the memory-budget footprint model
# (repro.core.membudget) asks the registry how much device scratch a
# kernel needs on top of its staged inputs — e.g. spmv's gathered
# xs/ys slices.  Estimators take keyword shape hints and return bytes;
# unknown kernels price as 0 so the model degrades gracefully.
#
# Every estimator also understands a ``devices`` hint (default 1): the
# mesh-cooperative streaming executor spreads one wave's work over a
# device mesh, so scratch that scales with item/tile counts is priced
# per device as ceil(count / devices) — the worst single device after
# an LPT split, which is what a per-device memory budget must bound.
_WORKSPACE: dict[str, Callable[..., int]] = {}


def _per_device(count: int, devices: int) -> int:
    """Worst-device share of ``count`` items split over ``devices``."""
    d = max(int(devices), 1)
    return -(-int(count) // d)


def register_workspace(name: str) -> Callable[[Callable], Callable]:
    """Decorator: register a workspace-bytes estimator for kernel ``name``."""

    def deco(fn: Callable[..., int]) -> Callable:
        _WORKSPACE[name] = fn
        return fn

    return deco


def registered_workspaces() -> tuple[str, ...]:
    """Names with a workspace estimator (declaration-typo guard)."""
    return tuple(_WORKSPACE)


def workspace_bytes(name, **shape_hints) -> int:
    """Estimated scratch bytes for ``name`` given shape hints (0 if none).

    ``name`` may be a sequence of kernel names, priced as the *maximum*
    over them — how a direction-optimizing plan charges for whichever
    of its push/pull dense variants is costlier, so a mid-stream switch
    never exceeds a budget the planner verified."""
    if not isinstance(name, str):
        return max((workspace_bytes(nm, **shape_hints) for nm in name),
                   default=0)
    fn = _WORKSPACE.get(name)
    return int(fn(**shape_hints)) if fn is not None else 0


def max_workspace_bytes(**shape_hints) -> int:
    """Worst case over every registered estimator — what the footprint
    model charges when an algorithm does not name its dense kernel."""
    return max(
        (int(fn(**shape_hints)) for fn in _WORKSPACE.values()), default=0
    )


# ``nd`` means "tiles staged in the batch" for every estimator below.
@register_workspace("spmv_tiles")
def _spmv_workspace(nd: int, tile_dim: int, devices: int = 1) -> int:
    # gathered xs + produced ys, one (nd, T) float32 slab each
    return 2 * _per_device(nd, devices) * tile_dim * 4


# CSR estimators: what the sparse/CSR path stages or scratches per wave.
# They take their own hints (``csr_edges``, ``items``/``depth``) and
# swallow the dense hints so max_workspace_bytes stays callable with
# (nd, tile_dim) alone.
@register_workspace("csr_slice")
def _csr_slice_workspace(csr_edges: int = 0, devices: int = 1,
                         **_hints) -> int:
    # the conformal CSR row slices staged as the wave's ctx.indices
    # (int32 per adjacency entry) — see BlockStore.csr_slices.  A mesh
    # device stages only its own tasks' row slices, hence the split.
    return _per_device(int(csr_edges) * 4, devices)


@register_workspace("csr_bucket_search")
def _csr_bucket_search_workspace(items: int = 0, depth: int = 0,
                                 devices: int = 1, **_hints) -> int:
    # TC-style membership test over staged CSR slices: gathered values
    # plus lo/hi binary-search bounds, one (items, depth) int32 each
    return 3 * _per_device(items, devices) * int(depth) * 4


@register_workspace("stage_arena")
def _stage_arena_workspace(slab_bytes: int = 0, depth: int = 2,
                           devices: int = 1, **_hints) -> int:
    # Pipelined staging (repro.core.stream._StagePipeline) keeps up to
    # ``depth`` assembled host slabs in flight plus the one crossing the
    # bus: the arena's pooled buffers are bounded by (depth + 1) × the
    # largest slab.  Host-side memory — the *device* bound stays the
    # per-slab ≤ budget invariant (at most current + prefetch resident),
    # but the footprint model prices the arena so callers can see the
    # true steady-state staging residency.
    return _per_device(int(slab_bytes) * (max(int(depth), 1) + 1), devices)


@register_workspace("frontier_tiles")
def _frontier_workspace(nd: int, tile_dim: int, devices: int = 1) -> int:
    # gathered frontier columns (bool) + candidate mins (int32)
    return _per_device(nd, devices) * tile_dim * (1 + 4)


@register_workspace("tc_tiles")
def _tc_workspace(nd: int, tile_dim: int, devices: int = 1) -> int:
    # the gathered tile operands of the masked matmul (one per staged
    # tile: each triple reads its 3 tiles, nd counts all of them)
    return _per_device(nd, devices) * tile_dim * tile_dim * 4


# ----------------------------------------------------------------------
# Built-in registrations for the dense-path tile kernels.  Pallas
# implementations import lazily inside the wrapper so merely selecting
# the backend never pays (or breaks on) the Pallas import.
def _register_builtin() -> None:
    import jax.numpy as jnp

    from . import ref

    @register_kernel("spmv_tiles", "reference")
    def _spmv_reference(tiles, xs):
        return ref.spmv_tiles_ref(tiles, xs)

    @register_kernel("spmv_tiles", "xla")
    def _spmv_xla(tiles, xs):
        return jnp.einsum("brc,br->bc", tiles, xs)

    @register_kernel("spmv_tiles", "pallas")
    def _spmv_pallas(tiles, xs):
        from . import ops

        return ops.spmv_tiles(tiles, xs)

    @register_kernel("frontier_tiles", "reference")
    def _frontier_reference(tiles, fcols):
        return ref.frontier_tiles_ref(tiles, fcols)

    @register_kernel("frontier_tiles", "xla")
    def _frontier_xla(tiles, fcols):
        t = tiles.shape[-1]
        colid = jnp.arange(t, dtype=jnp.int32)[None, None, :]
        masked = jnp.where((tiles > 0) & fcols[:, None, :], colid, ref.INT_MAX)
        return masked.min(axis=2)

    @register_kernel("frontier_tiles", "pallas")
    def _frontier_pallas(tiles, fcols):
        from . import ops

        return ops.frontier_tiles(tiles, fcols)

    @register_kernel("tc_tiles", "reference")
    def _tc_reference(a_ik, a_jk, a_ij):
        return ref.tc_tiles_ref(a_ik, a_jk, a_ij)

    @register_kernel("tc_tiles", "xla")
    def _tc_xla(a_ik, a_jk, a_ij):
        wedges = jnp.einsum("brc,bsc->brs", a_ik, a_jk)
        return jnp.sum(wedges * a_ij)

    @register_kernel("tc_tiles", "pallas")
    def _tc_pallas(a_ik, a_jk, a_ij):
        from . import ops

        return ops.tc_tiles(a_ik, a_jk, a_ij)

    for _name in ref.HOST_EXECUTABLE:
        register_host_executable(_name)


_register_builtin()
