"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function is the mathematical definition of the corresponding kernel
in this package; kernel tests sweep shapes/dtypes and
``assert_allclose`` against these.

Host-compute contract
---------------------
The oracles double as the *host CPU* implementations for heterogeneous
co-scheduling (:mod:`repro.core.stream`'s host lane): they are pure
``jnp`` with no Pallas/XLA-custom-call dependency, so they execute
eagerly on the CPU backend against host-side store views and produce
the same integer/boolean results as the device paths (dense and sparse
formulations of each algorithm agree per block-list).  A kernel name in
:data:`HOST_EXECUTABLE` certifies exactly that; the registry exposes it
via :func:`repro.kernels.registry.host_executable`, and the streaming
executor refuses to peel tasks whose algorithm depends on a kernel
outside the set.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT_MAX = jnp.int32(2**31 - 1)

#: Kernel names whose reference oracle is safe to run eagerly on the
#: host CPU (pure jnp, deterministic, bit-identical int/bool results).
HOST_EXECUTABLE = ("spmv_tiles", "frontier_tiles", "tc_tiles")


def tc_tiles_ref(a_ik: jnp.ndarray, a_jk: jnp.ndarray, a_ij: jnp.ndarray) -> jnp.ndarray:
    """Σ_b Σ_{r,s} (A_ik[b] · A_jk[b]ᵀ)[r,s] * A_ij[b][r,s]  → scalar f32.

    The per-block-list triangle count of the dense MXU path: wedge counts
    masked by the edge block.
    """
    w = jnp.einsum(
        "brc,bsc->brs", a_ik.astype(jnp.float32), a_jk.astype(jnp.float32)
    )
    return jnp.sum(w * a_ij.astype(jnp.float32))


def spmv_tiles_ref(tiles: jnp.ndarray, xs: jnp.ndarray) -> jnp.ndarray:
    """y[b] = A[b]ᵀ · x[b] for a batch of dense blocks — (nd,T,T),(nd,T)→(nd,T)."""
    return jnp.einsum("brc,br->bc", tiles.astype(jnp.float32), xs.astype(jnp.float32))


def frontier_tiles_ref(tiles: jnp.ndarray, fcols: jnp.ndarray) -> jnp.ndarray:
    """Bottom-up BFS tile step: per tile row, the smallest local column c
    with an edge into the frontier, else INT_MAX — (nd,T,T),(nd,T)→(nd,T) i32."""
    t = tiles.shape[-1]
    colid = jnp.arange(t, dtype=jnp.int32)[None, None, :]
    hit = (tiles > 0) & (fcols[:, None, :] > 0)
    return jnp.where(hit, colid, INT_MAX).min(axis=2)


def spmv_ell_ref(idx, valid, x):
    """(B,R,K) gather-and-mask row sums: y[b,r] = Σ_k x[b, idx[b,r,k]]·valid."""
    gathered = jax.vmap(lambda xi, ii: xi[ii])(x, idx)
    return jnp.sum(gathered * valid.astype(x.dtype), axis=2)


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """Plain softmax attention oracle — q,k,v: (B, H, S, D) → (B, H, S, D)."""
    d = q.shape[-1]
    scale = (d ** -0.5) if scale is None else scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        s_q, s_k = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((s_q, s_k), bool), k=s_k - s_q)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)
