"""Pallas TPU kernel: batched dense-block SpMV (PageRank's K_D hot spot).

y[b] = A[b]ᵀ · x[b] over the packed bitmap tiles: each grid step loads a
(T, bt) column panel of one tile plus the (T,) rank slice and produces a
(bt,) partial output — ``x · A_panel`` is a (1, T) × (T, bt) MXU matmul.
VMEM working set per step: T·bt + T floats (bt=128, T≤1024 → ≤0.6 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, x_ref, y_ref):
    a = a_ref[0].astype(jnp.float32)        # (T, bt) column panel
    x = x_ref[0].astype(jnp.float32)        # (T,)
    y_ref[0, :] = jax.lax.dot_general(
        x[None, :], a, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[0]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def spmv_tiles(tiles, xs, *, block_t: int = 128, interpret: bool = True):
    """(nd, T, T) tiles × (nd, T) slices → (nd, T): per-tile Aᵀx."""
    nb, t, _ = tiles.shape
    bt = min(block_t, t)
    assert t % bt == 0
    return pl.pallas_call(
        _kernel,
        grid=(nb, t // bt),
        in_specs=[
            pl.BlockSpec((1, t, bt), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, t), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt), lambda b, c: (b, c)),
        out_shape=jax.ShapeDtypeStruct((nb, t), jnp.float32),
        interpret=interpret,
    )(tiles, xs)
