"""Pallas TPU kernels for the compute hot spots (validated interpret=True).

tc_tile      — dense-block triangle counting (masked MXU matmul)
spmv_tile    — batched dense-block SpMV (PageRank dense path)
frontier_tile— bottom-up BFS frontier probe (masked row reduction)
attn_tile    — flash-style fused attention (LM substrate)
ops          — jit'd wrappers w/ TPU/interpret dispatch
ref          — pure-jnp oracles for all of the above
registry     — kernel × backend ("reference"|"xla"|"pallas") dispatch table

``ops`` (and through it the Pallas kernel modules) imports lazily and is
``None`` when no Pallas runtime exists; the registry's fallback chain
(pallas → xla → reference) keeps every kernel callable regardless.
"""
from . import ref, registry
from .registry import get_kernel, register_kernel, resolve_backend, pallas_available

try:  # Pallas import can fail on minimal hosts; the registry degrades.
    from . import ops
except Exception:  # pragma: no cover
    ops = None  # type: ignore[assignment]

__all__ = [
    "ops", "ref", "registry",
    "get_kernel", "register_kernel", "resolve_backend", "pallas_available",
]
