"""Pallas TPU kernels for the compute hot spots (validated interpret=True).

tc_tile      — dense-block triangle counting (masked MXU matmul)
spmv_tile    — batched dense-block SpMV (PageRank dense path)
frontier_tile— bottom-up BFS frontier probe (masked row reduction)
attn_tile    — flash-style fused attention (LM substrate)
ops          — jit'd wrappers w/ TPU/interpret dispatch
ref          — pure-jnp oracles for all of the above
"""
from . import ops, ref

__all__ = ["ops", "ref"]
