"""jit'd dispatch wrappers for the Pallas kernels.

On a TPU backend the compiled kernels run natively; everywhere else
(this CPU container, unit tests) they execute in ``interpret=True``
mode, which runs the same kernel body per grid step in Python/XLA and
validates the BlockSpec tiling logic.  ``set_interpret`` overrides the
auto-detection (tests use it to force interpret explicitly).
"""
from __future__ import annotations

import jax

from .tc_tile import tc_tiles as _tc_tiles
from .spmv_tile import spmv_tiles as _spmv_tiles
from .frontier_tile import frontier_tiles as _frontier_tiles
from .attn_tile import flash_attention as _flash_attention

_FORCE_INTERPRET: bool | None = None


def set_interpret(value: bool | None) -> None:
    global _FORCE_INTERPRET
    _FORCE_INTERPRET = value


def _interpret() -> bool:
    if _FORCE_INTERPRET is not None:
        return _FORCE_INTERPRET
    return jax.default_backend() != "tpu"


def tc_tiles(a_ik, a_jk, a_ij, *, block_t: int = 128):
    return _tc_tiles(a_ik, a_jk, a_ij, block_t=block_t, interpret=_interpret())


def spmv_tiles(tiles, xs, *, block_t: int = 128):
    return _spmv_tiles(tiles, xs, block_t=block_t, interpret=_interpret())


def frontier_tiles(tiles, fcols, *, block_t: int = 128):
    return _frontier_tiles(tiles, fcols, block_t=block_t, interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return _flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=_interpret(),
    )
