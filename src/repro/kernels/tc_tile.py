"""Pallas TPU kernel: dense-block triangle counting (the K_D hot spot).

Computes  Σ_b Σ_{r,s} (A_ik[b] · A_jk[b]ᵀ)[r,s] ∘ A_ij[b][r,s]  over a
batch of packed bitmap tiles.  This is the MXU adaptation of the paper's
GPU triangle-counting kernel (Listing 5): the list intersection for a
whole (bt × bt) patch of edges becomes one (bt, T) × (T, bt) matmul.

Tiling: grid (B, T/bt, T/bt); each step loads one row-panel of A_ik, one
row-panel of A_jk and the (bt, bt) mask patch of A_ij into VMEM — the
working set is 2·bt·T + bt² floats (bt=128, T≤1024 → ≤1.1 MiB), well
inside VMEM, and the contraction dims are multiples of 128 for the MXU.
The scalar partial sums accumulate in a (1, 1) VMEM block across the
sequential grid steps of a batch entry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ik_ref, a_jk_ref, a_ij_ref, out_ref):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ik_ref[0].astype(jnp.float32)   # (bt, T)
    b = a_jk_ref[0].astype(jnp.float32)   # (bt, T)
    m = a_ij_ref[0].astype(jnp.float32)   # (bt, bt)
    w = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                      # (bt, bt) wedge counts on the MXU
    out_ref[0, 0] += jnp.sum(w * m)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def tc_tiles(a_ik, a_jk, a_ij, *, block_t: int = 128, interpret: bool = True):
    """Batched masked-matmul triangle count: (B,T,T)×3 → scalar f32."""
    nb, t, _ = a_ik.shape
    bt = min(block_t, t)
    assert t % bt == 0, f"tile dim {t} not divisible by block {bt}"
    grid = (nb, t // bt, t // bt)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, t), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bt, t), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bt, bt), lambda b, i, j: (b, i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, i, j: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        interpret=interpret,
    )(a_ik, a_jk, a_ij)
    return jnp.sum(out)
