"""Quickstart: the five paper algorithms through the public PGAbB-JAX API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import rmat, build_block_store
from repro.algorithms import (
    pagerank, shiloach_vishkin, connected_components, bfs, triangle_count,
)

# a skewed RMAT graph (kron-class, the paper's hardest case for balance)
g = rmat(12, 8, seed=7)
print(f"graph: n={g.n} m={g.m}")

# partition into 4x4 conformal blocks — one line; the engine schedules
# dense blocks onto the MXU path, sparse ones onto the VPU path
store = build_block_store(g, 4)

ranks = pagerank(store)
print(f"pagerank: sum={ranks.sum():.4f} top vertex={int(np.argmax(ranks))}")

comp = shiloach_vishkin(store)
print(f"shiloach-vishkin: {len(np.unique(comp))} components")

comp2 = connected_components(store)   # Afforest
print(f"afforest:         {len(np.unique(comp2))} components")

out = bfs(store, source=int(np.argmax(np.diff(g.indptr))))
reached = int((out["dist"] < 2**31 - 1).sum())
print(f"bfs: reached {reached}/{g.n}, max depth "
      f"{int(out['dist'][out['dist'] < 2**31-1].max())}")

nt = triangle_count(g, p=4)
print(f"triangles: {nt}")
