"""Quickstart: the paper algorithms through the compiled-Plan API.

Build once (`compile_plan`), execute many times (`plan.run`), reuse the
same compiled plan across graphs with the same padded shapes — and add
`memory_budget=` to stream the same computation out-of-core when the
edge set must not live on the device whole.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import rmat, from_edges, build_block_store, compile_plan
from repro.algorithms import (
    pagerank_algorithm, sv_algorithm, afforest_algorithm, bfs_algorithm,
    triangle_count,
)

# a skewed RMAT graph (kron-class, the paper's hardest case for balance)
g = rmat(12, 8, seed=7)
print(f"graph: n={g.n} m={g.m}")

# partition into 4x4 conformal blocks — one line; compile_plan schedules
# dense blocks onto the MXU path, sparse ones onto the VPU path
store = build_block_store(g, 4)

# build/compile once ...
plan = compile_plan(pagerank_algorithm(), store, backend="xla")
# ... execute; the schedule is a first-class, inspectable artifact
res = plan.run()
ranks = res.result
st = plan.schedule.stats
print(f"pagerank: sum={ranks.sum():.4f} top vertex={int(np.argmax(ranks))} "
      f"({st['num_tasks']} tasks, {st['dense_tasks']} dense)")

# cross-graph plan reuse: a second graph with the same padded shapes
# runs through the already-compiled step — zero recompilation
perm = np.random.default_rng(1).permutation(g.n)
s, d = g.coo()
g2 = from_edges(perm[s], perm[d], n=g.n)
store2 = build_block_store(g2, 4)
ranks2 = plan.run(store2).result
print(f"pagerank on relabeled graph: sum={ranks2.sum():.4f} "
      f"(compile_count={plan.compile_count})")

comp = compile_plan(sv_algorithm(), store).run().result
print(f"shiloach-vishkin: {len(np.unique(comp))} components")

comp2 = compile_plan(afforest_algorithm(), store).run().result
print(f"afforest:         {len(np.unique(comp2))} components")

src = int(np.argmax(np.diff(g.indptr)))
out = compile_plan(bfs_algorithm(src), store).run().result
reached = int((out["dist"] < 2**31 - 1).sum())
print(f"bfs: reached {reached}/{g.n}, max depth "
      f"{int(out['dist'][out['dist'] < 2**31-1].max())}")

# the one-shot wrappers still exist for quick calls
nt = triangle_count(g, p=4)
print(f"triangles: {nt}")

# out-of-core: the same compile_plan call under a device-memory budget
# streams double-buffered waves whose staged bytes each fit the budget
# (see docs/architecture.md for the accounting model)
splan = compile_plan(pagerank_algorithm(), store, memory_budget="512KB")
sres = splan.run()
st = sres.schedule_stats["streaming"]
print(f"streamed pagerank: sum={sres.result.sum():.4f} "
      f"waves={st['num_waves']} "
      f"max_wave_bytes={max(st['bytes_per_wave'])} (≤ {st['budget_bytes']}) "
      f"overlap={st['overlap_efficiency']:.2f}")
