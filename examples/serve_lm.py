"""Batched serving example: cached single-token decode loop.

    PYTHONPATH=src python examples/serve_lm.py --arch hymba-1.5b
"""
import argparse
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or ["--arch", "qwen2.5-32b"])
from repro.launch.serve import main  # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2.5-32b")
ap.add_argument("--tokens", type=int, default=24)
args = ap.parse_args()
raise SystemExit(
    main(["--arch", args.arch, "--smoke", "--batch", "4",
          "--tokens", str(args.tokens)])
)
