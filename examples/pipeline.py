"""The paper's §1 motivating pipeline, end to end:

connected components → extract largest component → BFS reorder →
triangle count / clustering — all through the block-based API.

    PYTHONPATH=src python examples/pipeline.py
"""
import numpy as np

from repro.core import rmat, from_edges, build_block_store, compile_plan
from repro.algorithms import afforest_algorithm, bfs_algorithm, triangle_count

g = rmat(12, 8, seed=42)
print(f"input graph: n={g.n} m={g.m}")

# 1. connected components → giant component
store = build_block_store(g, 4)
comp = compile_plan(afforest_algorithm(), store).run().result
labels, counts = np.unique(comp, return_counts=True)
giant = labels[np.argmax(counts)]
members = np.where(comp == giant)[0]
print(f"giant component: {members.size} vertices")

# 2. extract + reindex
remap = -np.ones(g.n, np.int64)
remap[members] = np.arange(members.size)
s, d = g.coo()
keep = (comp[s] == giant) & (comp[d] == giant)
g2 = from_edges(remap[s[keep]], remap[d[keep]], n=members.size)

# 3. BFS from the max-degree vertex → level ordering
store2 = build_block_store(g2, 4)
root = int(np.argmax(np.diff(g2.indptr)))
out = compile_plan(bfs_algorithm(root), store2).run().result
order = np.argsort(out["dist"], kind="stable")
perm = np.empty(g2.n, np.int64)
perm[order] = np.arange(g2.n)
s2, d2 = g2.coo()
g3 = from_edges(perm[s2], perm[d2], n=g2.n)
print(f"bfs reorder done (root {root}, depth "
      f"{int(out['dist'][out['dist'] < 2**31-1].max())})")

# 4. triangle count on the reordered graph
nt = triangle_count(g3, p=4)
avg_deg = g3.m / g3.n
print(f"triangles: {nt}  (global clustering proxy: "
      f"{3 * nt / max(1, (avg_deg * (avg_deg - 1) / 2) * g3.n):.4f})")
