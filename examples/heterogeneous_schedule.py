"""Scheduler anatomy demo: how PGAbB-JAX routes tasks (paper §4.4).

Shows, for a skewed graph: the E-estimates, the weight-sorted task
order, which tasks the MXU (dense) path claims under the cut-off, the
LPT packing across 8 virtual devices, and the resulting makespan ratio.

    PYTHONPATH=src python examples/heterogeneous_schedule.py
"""
import numpy as np

from repro.core import rmat, degree_order, build_block_store, compile_plan
from repro.algorithms import pagerank_algorithm

# skewed RMAT; degree ordering concentrates hub-hub edges into a dense
# corner block (exactly the structure the paper's TC work exploits)
g, _ = degree_order(rmat(12, 16, seed=3))
store = build_block_store(g, 8)
# compile_plan builds the schedule as a first-class artifact; it is
# inspectable on the plan before (or without) ever executing it
plan = compile_plan(
    pagerank_algorithm(), store, num_devices=8, mode="hybrid",
    dense_density=0.02, dense_frac=0.5, tile_dim=1024,
)
sched = plan.schedule

print("task  weight(E)   path    device")
for t in sched.order[:16]:
    path = "MXU/dense" if sched.dense_task_mask[t] else "VPU/sparse"
    print(f"{t:4d}  {sched.weights[t]:9.0f}   {path:9s}  {sched.device_assignment[t]}")
print("...")
st = sched.stats
print(f"\ntasks={st['num_tasks']} dense={st['dense_tasks']} "
      f"dense_weight={st['dense_weight_frac']:.2f} "
      f"LPT makespan ratio={st['makespan_ratio']:.3f} (1.0 = perfect balance)")
