"""End-to-end LM training driver on the framework substrates:
synthetic pipeline -> unified model -> AdamW -> atomic checkpoints.

Default: a ~20M-param qwen2.5-family model for 200 steps on CPU (a few
minutes).  `--full-100m` scales to ~100M params (slower; same code runs
the 32B config on a real mesh via launch/train.py).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--full-100m]
"""
import argparse
from dataclasses import replace

from repro.configs import get_smoke
from repro.train import TrainConfig, TrainLoop

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--full-100m", action="store_true")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

cfg = get_smoke("qwen2.5-32b")
if args.full_100m:
    cfg = replace(cfg, name="qwen-100m", n_layers=8, d_model=512, n_heads=8,
                  n_kv_heads=2, d_ff=2048, vocab=32000)
else:
    cfg = replace(cfg, name="qwen-20m", n_layers=4, d_model=256, n_heads=8,
                  n_kv_heads=2, d_ff=1024, vocab=8192)

tc = TrainConfig(steps=args.steps, batch=8, seq=256, base_lr=1e-3,
                 warmup_steps=20, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                 log_every=10)
loop = TrainLoop(cfg, tc)
out = loop.run(on_step=lambda m: print(
    f"step {m['step']:4d}  nll {m['nll']:.4f}  gnorm {m['grad_norm']:.2f} "
    f"{m['tokens_per_s']:.0f} tok/s"))
h = out["history"]
print(f"\n{cfg.name}: nll {h[0]['nll']:.3f} -> {h[-1]['nll']:.3f} over "
      f"{args.steps} steps  (resume-safe: rerun to continue from ckpt)")
